"""X-pencil interaction kernel (paper §5.2) as a Pallas TPU kernel.

Schedule (mirrors Algorithm 5, adapted per DESIGN.md §2):

  grid = (nz, ny, 9)
    (z, y)  — one program per target X-pencil (the paper's thread-block);
    k       — the 9 (dz, dy) neighbor pencils, innermost so the output block
              stays resident in VMEM while neighbors stream through
              (the paper's "load one pencil at a time" loop, with the
              HBM->VMEM DMA double-buffered by the Pallas pipeline — the TPU
              version of overlapping the next pencil's copy with compute).

  BlockSpec staging:
    target pencil  block (1, 1, (nx+2)*m_c) at (z+1, y+1)      — "registers"
    source pencil  block (1, 1, (nx+2)*m_c) at (z+k/3, y+k%3)  — "shared mem"
    outputs        block (1, 1, nx*m_c), revisited across k, accumulated.

  The contiguous 3*m_c X-window of each target cell is built from three
  static slices of the staged source row (the dense slot layout makes the
  window contiguous — the paper needs its local-offset prefix sum for this).

VMEM per step: 8 pencil rows + 4 output rows ~ (12*nx + 16)*m_c*4 bytes
(nx=32, m_c=128 -> ~200 KB), far under budget: exactly the paper's point that
pencils, unlike sub-boxes, leave head-room (occupancy there, double-buffering
here). Lane alignment: rows are contiguous f32 vectors; choosing m_c as a
multiple of 8 keeps slices sublane-aligned (``suggest_m_c`` does this).

``xpencil_sparse_forces`` below is the occupancy-compacted variant: its grid
runs over the *active* pencils only, with the active-index list
scalar-prefetched so the BlockSpec index maps become data-dependent.
``xpencil_packed_forces`` is the packed-row (CSR) variant on top of that:
each DMA moves ``row_cap`` packed slots plus a prefix-sum offset row
instead of a dense ``(nx+2)*m_c`` row — bytes proportional to the
particles, the paper's few-particles-per-cell fix.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.binning import EMPTY_POS
from ..core.interactions import PairKernel
from ._platform import resolve_interpret

Array = jnp.ndarray


def _window3(row: Array, nx: int, m_c: int) -> Array:
    """(nx+2)*m_c source row -> (nx, 3*m_c) per-cell contiguous windows."""
    cells = row.reshape(nx + 2, m_c)
    return jnp.concatenate(
        [cells[0:nx], cells[1:nx + 1], cells[2:nx + 2]], axis=-1)


def _pencil_contrib(trows: Tuple[Array, Array, Array, Array],
                    srows: Tuple[Array, Array, Array, Array],
                    *, nx: int, m_c: int, kernel: PairKernel,
                    cutoff2: float):
    """One (dz, dy) step: target pencil rows x one staged source pencil row.

    ``trows``/``srows`` are the raw padded rows (length ``(nx+2)*m_c``) of
    x, y, z, slot_id. Returns 4 flat ``(nx*m_c,)`` contributions. Shared by
    the dense and compacted kernel bodies so compaction cannot change a
    computed value.
    """
    lo, hi = m_c, (nx + 1) * m_c
    xt, yt, zt, it = trows
    tx = xt[lo:hi].reshape(nx, m_c, 1)
    ty = yt[lo:hi].reshape(nx, m_c, 1)
    tz = zt[lo:hi].reshape(nx, m_c, 1)
    tid = it[lo:hi].reshape(nx, m_c, 1)

    xs, ys, zs, is_ = srows
    sx = _window3(xs, nx, m_c).reshape(nx, 1, 3 * m_c)
    sy = _window3(ys, nx, m_c).reshape(nx, 1, 3 * m_c)
    sz = _window3(zs, nx, m_c).reshape(nx, 1, 3 * m_c)
    sid = _window3(is_, nx, m_c).reshape(nx, 1, 3 * m_c)

    ddx, ddy, ddz = tx - sx, ty - sy, tz - sz
    r2 = ddx * ddx + ddy * ddy + ddz * ddz
    mask = (sid != tid) & (sid >= 0) & (tid >= 0) & (r2 < cutoff2) & (r2 > 0.0)
    r2s = jnp.where(mask, r2, 1.0)
    w = mask.astype(ddx.dtype)
    s = kernel.coeff(r2s) * w
    pot = kernel.potential(r2s) * w
    return ((s * ddx).sum(-1).reshape(nx * m_c),
            (s * ddy).sum(-1).reshape(nx * m_c),
            (s * ddz).sum(-1).reshape(nx * m_c),
            pot.sum(-1).reshape(nx * m_c))


def _kernel(xt_ref, yt_ref, zt_ref, it_ref,
            xs_ref, ys_ref, zs_ref, is_ref,
            fx_ref, fy_ref, fz_ref, pot_ref,
            *, nx: int, m_c: int, kernel: PairKernel, cutoff2: float):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        fx_ref[...] = jnp.zeros_like(fx_ref)
        fy_ref[...] = jnp.zeros_like(fy_ref)
        fz_ref[...] = jnp.zeros_like(fz_ref)
        pot_ref[...] = jnp.zeros_like(pot_ref)

    fx, fy, fz, pot = _pencil_contrib(
        (xt_ref[0, 0, :], yt_ref[0, 0, :], zt_ref[0, 0, :], it_ref[0, 0, :]),
        (xs_ref[0, 0, :], ys_ref[0, 0, :], zs_ref[0, 0, :], is_ref[0, 0, :]),
        nx=nx, m_c=m_c, kernel=kernel, cutoff2=cutoff2)

    fx_ref[...] += fx.reshape(1, 1, nx * m_c)
    fy_ref[...] += fy.reshape(1, 1, nx * m_c)
    fz_ref[...] += fz.reshape(1, 1, nx * m_c)
    pot_ref[...] += pot.reshape(1, 1, nx * m_c)


@functools.partial(jax.jit, static_argnames=("nx", "m_c", "kernel", "cutoff2", "interpret"))
def xpencil_forces(planes: dict, slot_id: Array, *, nx: int, m_c: int,
                   kernel: PairKernel, cutoff2: float,
                   interpret: Optional[bool] = None
                   ) -> Tuple[Array, Array, Array, Array]:
    """Run the X-pencil kernel over padded planes.

    Args:
      planes: dict with "x","y","z" padded planes (nz+2, ny+2, (nx+2)*m_c).
      slot_id: matching int32 plane, -1 for empty slots.
      interpret: None = native on TPU, interpreter elsewhere (matching
        ``InteractionPlan.interpret``); bool forces the mode.
    Returns:
      (fx, fy, fz, pot), each (nz, ny, nx*m_c) over interior slots.
    """
    interpret = resolve_interpret(interpret)
    x = planes["x"]
    nzp, nyp, w = x.shape
    nz, ny = nzp - 2, nyp - 2
    row_block = pl.BlockSpec((1, 1, w), lambda z, y, k: (z + 1, y + 1, 0))
    nbr_block = pl.BlockSpec((1, 1, w), lambda z, y, k: (z + k // 3, y + k % 3, 0))
    out_block = pl.BlockSpec((1, 1, nx * m_c), lambda z, y, k: (z, y, 0))
    out_shape = jax.ShapeDtypeStruct((nz, ny, nx * m_c), x.dtype)

    body = functools.partial(_kernel, nx=nx, m_c=m_c, kernel=kernel,
                             cutoff2=float(cutoff2))
    fx, fy, fz, pot = pl.pallas_call(
        body,
        grid=(nz, ny, 9),
        in_specs=[row_block] * 4 + [nbr_block] * 4,
        out_specs=[out_block] * 4,
        out_shape=[out_shape] * 3 + [jax.ShapeDtypeStruct(
            (nz, ny, nx * m_c), x.dtype)],
        interpret=interpret,
    )(x, planes["y"], planes["z"], slot_id,
      x, planes["y"], planes["z"], slot_id)
    return fx, fy, fz, pot


# --------------------------------------------------------------------------
# occupancy-compacted variant: grid over *active* pencils only
# --------------------------------------------------------------------------
#
# The dense kernel's grid is (nz, ny, 9) — every pencil pays 10 row DMAs and
# a full masked pair reduction whether or not it holds particles. Here the
# grid is (max_active, 9): the active-pencil index list is *scalar-
# prefetched* (``pltpu.PrefetchScalarGridSpec``), so the BlockSpec index
# maps can read it before each step and DMA exactly the rows of the a-th
# active pencil — data-dependent staging, the TPU analogue of a compacted
# thread-block launch. Outputs are compact (max_active, nx*m_c) rows that
# the caller scatters back into the dense planes (padding rows recompute
# pencil 0 and are dropped by the scatter).


def _sparse_kernel(act_ref,                         # scalar-prefetched ids
                   xt_ref, yt_ref, zt_ref, it_ref,
                   xs_ref, ys_ref, zs_ref, is_ref,
                   fx_ref, fy_ref, fz_ref, pot_ref,
                   *, nx: int, m_c: int, kernel: PairKernel, cutoff2: float):
    del act_ref  # consumed by the BlockSpec index maps, not the body
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        fx_ref[...] = jnp.zeros_like(fx_ref)
        fy_ref[...] = jnp.zeros_like(fy_ref)
        fz_ref[...] = jnp.zeros_like(fz_ref)
        pot_ref[...] = jnp.zeros_like(pot_ref)

    fx, fy, fz, pot = _pencil_contrib(
        (xt_ref[0, 0, :], yt_ref[0, 0, :], zt_ref[0, 0, :], it_ref[0, 0, :]),
        (xs_ref[0, 0, :], ys_ref[0, 0, :], zs_ref[0, 0, :], is_ref[0, 0, :]),
        nx=nx, m_c=m_c, kernel=kernel, cutoff2=cutoff2)

    fx_ref[...] += fx.reshape(1, nx * m_c)
    fy_ref[...] += fy.reshape(1, nx * m_c)
    fz_ref[...] += fz.reshape(1, nx * m_c)
    pot_ref[...] += pot.reshape(1, nx * m_c)


@functools.partial(jax.jit, static_argnames=("nx", "ny", "m_c", "kernel",
                                             "cutoff2", "interpret"))
def xpencil_sparse_forces(planes: dict, slot_id: Array, active_zy: Array, *,
                          nx: int, ny: int, m_c: int, kernel: PairKernel,
                          cutoff2: float, interpret: Optional[bool] = None
                          ) -> Tuple[Array, Array, Array, Array]:
    """Run the compacted X-pencil kernel over the active pencils.

    Args:
      planes / slot_id: padded planes as in :func:`xpencil_forces`.
      active_zy: (max_active,) int32 linearized interior pencil ids
        ``z * ny + y``, padded with 0 (``binning.Occupancy.active``); the
        padding recomputes pencil 0 and must be dropped by the caller's
        scatter (``Occupancy.scatter_indices``).
    Returns:
      (fx, fy, fz, pot), each compact ``(max_active, nx*m_c)``: row ``a``
      holds the interior forces of pencil ``active_zy[a]``.
    """
    interpret = resolve_interpret(interpret)
    x = planes["x"]
    w = x.shape[-1]
    max_active = active_zy.shape[0]

    def tgt_map(a, k, act):
        return (act[a] // ny + 1, act[a] % ny + 1, 0)

    def nbr_map(a, k, act):
        return (act[a] // ny + k // 3, act[a] % ny + k % 3, 0)

    row_block = pl.BlockSpec((1, 1, w), tgt_map)
    nbr_block = pl.BlockSpec((1, 1, w), nbr_map)
    out_block = pl.BlockSpec((1, nx * m_c), lambda a, k, act: (a, 0))
    out_shape = jax.ShapeDtypeStruct((max_active, nx * m_c), x.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(max_active, 9),
        in_specs=[row_block] * 4 + [nbr_block] * 4,
        out_specs=[out_block] * 4,
    )
    body = functools.partial(_sparse_kernel, nx=nx, m_c=m_c, kernel=kernel,
                             cutoff2=float(cutoff2))
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=[out_shape] * 4,
        interpret=interpret,
    )(active_zy.astype(jnp.int32),
      x, planes["y"], planes["z"], slot_id,
      x, planes["y"], planes["z"], slot_id)


# --------------------------------------------------------------------------
# packed-row (CSR) variant: row_cap rows, offset-driven windows
# --------------------------------------------------------------------------
#
# The compacted kernel above still DMAs every active pencil's full dense
# (nx+2)*m_c row; in the few-particles-per-cell regime most of those bytes
# are sentinel padding. This variant reads the packed layout
# (``core.binning.PackedRows``) instead: each DMA moves ``row_cap`` packed
# slots plus an (nx+3)-entry offset row — bytes proportional to the
# particles, not to m_c. The scalar-prefetched active-row ids drive the
# BlockSpec index maps exactly as in the compacted kernel (the same
# data-dependent staging, composed with the packed rows' own CSR offsets,
# which stay *row-local* so a DMA'd row is self-describing); inside the
# body each target slot's 3-cell X-window is re-expanded to the dense
# (3*m_c,) shape by offset/length, so every pair term, mask and reduction
# is elementwise identical to the dense kernel's — bit-identical results.

def _packed_contrib(trows, srow, soff, *, nx: int, m_c: int, row_cap: int,
                    kernel: PairKernel, cutoff2: float):
    """One (dz, dy) step over packed rows.

    ``trows`` = (tx, ty, tz, tid, tcell) packed target row vectors, each
    ``(row_cap,)``; ``srow`` = (xs, ys, zs, ids) packed source row;
    ``soff`` = the source row's ``(nx+3,)`` cell offsets. Returns 4 flat
    ``(row_cap,)`` contributions, elementwise equal to what the dense
    body computes for the same particles.
    """
    tx, ty, tz, tid, tc = trows
    xs, ys, zs, ids = srow
    tcell = jnp.clip(tc, 1, nx)          # pad/ghost targets never unpacked

    j = jnp.arange(3 * m_c, dtype=jnp.int32)
    wcell = tcell[:, None] - 1 + j // m_c            # (row_cap, 3*m_c)
    rank = j % m_c
    start = jnp.take(soff, wcell.reshape(-1)).reshape(wcell.shape)
    cnt = jnp.take(soff, (wcell + 1).reshape(-1)).reshape(wcell.shape) - start
    valid = rank < cnt
    src = jnp.where(valid, start + rank, 0).reshape(-1)

    def expand(row, fill):
        vals = jnp.take(row, src).reshape(wcell.shape)
        return jnp.where(valid, vals, fill)

    sx = expand(xs, EMPTY_POS)
    sy = expand(ys, EMPTY_POS)
    sz = expand(zs, EMPTY_POS)
    sid = expand(ids, jnp.int32(-1))

    ddx = tx[:, None] - sx
    ddy = ty[:, None] - sy
    ddz = tz[:, None] - sz
    r2 = ddx * ddx + ddy * ddy + ddz * ddz
    mask = ((sid != tid[:, None]) & (sid >= 0) & (tid[:, None] >= 0)
            & (r2 < cutoff2) & (r2 > 0.0))
    r2s = jnp.where(mask, r2, 1.0)
    w = mask.astype(ddx.dtype)
    s = kernel.coeff(r2s) * w
    pot = kernel.potential(r2s) * w
    return ((s * ddx).sum(-1), (s * ddy).sum(-1), (s * ddz).sum(-1),
            pot.sum(-1))


def _packed_kernel(act_ref,                          # scalar-prefetched ids
                   xt_ref, yt_ref, zt_ref, it_ref, ct_ref,
                   xs_ref, ys_ref, zs_ref, is_ref, os_ref,
                   fx_ref, fy_ref, fz_ref, pot_ref,
                   *, nx: int, m_c: int, row_cap: int, kernel: PairKernel,
                   cutoff2: float):
    del act_ref  # consumed by the BlockSpec index maps, not the body
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        fx_ref[...] = jnp.zeros_like(fx_ref)
        fy_ref[...] = jnp.zeros_like(fy_ref)
        fz_ref[...] = jnp.zeros_like(fz_ref)
        pot_ref[...] = jnp.zeros_like(pot_ref)

    fx, fy, fz, pot = _packed_contrib(
        (xt_ref[0, 0, :], yt_ref[0, 0, :], zt_ref[0, 0, :], it_ref[0, 0, :],
         ct_ref[0, 0, :]),
        (xs_ref[0, 0, :], ys_ref[0, 0, :], zs_ref[0, 0, :], is_ref[0, 0, :]),
        os_ref[0, 0, :],
        nx=nx, m_c=m_c, row_cap=row_cap, kernel=kernel, cutoff2=cutoff2)

    fx_ref[...] += fx.reshape(1, row_cap)
    fy_ref[...] += fy.reshape(1, row_cap)
    fz_ref[...] += fz.reshape(1, row_cap)
    pot_ref[...] += pot.reshape(1, row_cap)


@functools.partial(jax.jit, static_argnames=("nx", "ny", "m_c", "row_cap",
                                             "kernel", "cutoff2",
                                             "interpret"))
def xpencil_packed_forces(planes: dict, slot_id: Array, slot_cell: Array,
                          cell_offsets: Array, active_zy: Array, *,
                          nx: int, ny: int, m_c: int, row_cap: int,
                          kernel: PairKernel, cutoff2: float,
                          interpret: Optional[bool] = None
                          ) -> Tuple[Array, Array, Array, Array]:
    """Run the packed-row X-pencil kernel over the given pencil rows.

    Args:
      planes / slot_id / slot_cell / cell_offsets: the packed layout's
        padded planes (``core.binning.PackedRows``) — planes and ids are
        ``(nz+2, ny+2, row_cap)``, offsets ``(nz+2, ny+2, nx+3)``.
      active_zy: (n_rows,) int32 linearized interior pencil ids
        ``z * ny + y`` to iterate — the full ``arange(nz * ny)`` for a
        dense sweep or an ``Occupancy.active`` list for a compacted one
        (padding recomputes pencil 0; drop it with ``scatter_indices``).
    Returns:
      (fx, fy, fz, pot), each compact ``(n_rows, row_cap)``: row ``a``
      holds the packed-slot forces of pencil ``active_zy[a]``.
    """
    interpret = resolve_interpret(interpret)
    x = planes["x"]
    n_rows = active_zy.shape[0]

    def tgt_map(a, k, act):
        return (act[a] // ny + 1, act[a] % ny + 1, 0)

    def nbr_map(a, k, act):
        return (act[a] // ny + k // 3, act[a] % ny + k % 3, 0)

    row_block = pl.BlockSpec((1, 1, row_cap), tgt_map)
    nbr_block = pl.BlockSpec((1, 1, row_cap), nbr_map)
    off_block = pl.BlockSpec((1, 1, nx + 3), nbr_map)
    out_block = pl.BlockSpec((1, row_cap), lambda a, k, act: (a, 0))
    out_shape = jax.ShapeDtypeStruct((n_rows, row_cap), x.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_rows, 9),
        in_specs=[row_block] * 5 + [nbr_block] * 4 + [off_block],
        out_specs=[out_block] * 4,
    )
    body = functools.partial(_packed_kernel, nx=nx, m_c=m_c,
                             row_cap=row_cap, kernel=kernel,
                             cutoff2=float(cutoff2))
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=[out_shape] * 4,
        interpret=interpret,
    )(active_zy.astype(jnp.int32),
      x, planes["y"], planes["z"], slot_id, slot_cell,
      x, planes["y"], planes["z"], slot_id, cell_offsets)
