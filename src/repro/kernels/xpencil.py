"""X-pencil interaction kernel (paper §5.2) as a Pallas TPU kernel.

Schedule (mirrors Algorithm 5, adapted per DESIGN.md §2):

  grid = (nz, ny, 9)
    (z, y)  — one program per target X-pencil (the paper's thread-block);
    k       — the 9 (dz, dy) neighbor pencils, innermost so the output block
              stays resident in VMEM while neighbors stream through
              (the paper's "load one pencil at a time" loop, with the
              HBM->VMEM DMA double-buffered by the Pallas pipeline — the TPU
              version of overlapping the next pencil's copy with compute).

  BlockSpec staging:
    target pencil  block (1, 1, (nx+2)*m_c) at (z+1, y+1)      — "registers"
    source pencil  block (1, 1, (nx+2)*m_c) at (z+k/3, y+k%3)  — "shared mem"
    outputs        block (1, 1, nx*m_c), revisited across k, accumulated.

  The contiguous 3*m_c X-window of each target cell is built from three
  static slices of the staged source row (the dense slot layout makes the
  window contiguous — the paper needs its local-offset prefix sum for this).

VMEM per step: 8 pencil rows + 4 output rows ~ (12*nx + 16)*m_c*4 bytes
(nx=32, m_c=128 -> ~200 KB), far under budget: exactly the paper's point that
pencils, unlike sub-boxes, leave head-room (occupancy there, double-buffering
here). Lane alignment: rows are contiguous f32 vectors; choosing m_c as a
multiple of 8 keeps slices sublane-aligned (``suggest_m_c`` does this).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.interactions import PairKernel
from ._platform import resolve_interpret

Array = jnp.ndarray


def _window3(row: Array, nx: int, m_c: int) -> Array:
    """(nx+2)*m_c source row -> (nx, 3*m_c) per-cell contiguous windows."""
    cells = row.reshape(nx + 2, m_c)
    return jnp.concatenate(
        [cells[0:nx], cells[1:nx + 1], cells[2:nx + 2]], axis=-1)


def _kernel(xt_ref, yt_ref, zt_ref, it_ref,
            xs_ref, ys_ref, zs_ref, is_ref,
            fx_ref, fy_ref, fz_ref, pot_ref,
            *, nx: int, m_c: int, kernel: PairKernel, cutoff2: float):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        fx_ref[...] = jnp.zeros_like(fx_ref)
        fy_ref[...] = jnp.zeros_like(fy_ref)
        fz_ref[...] = jnp.zeros_like(fz_ref)
        pot_ref[...] = jnp.zeros_like(pot_ref)

    lo, hi = m_c, (nx + 1) * m_c
    tx = xt_ref[0, 0, lo:hi].reshape(nx, m_c, 1)
    ty = yt_ref[0, 0, lo:hi].reshape(nx, m_c, 1)
    tz = zt_ref[0, 0, lo:hi].reshape(nx, m_c, 1)
    tid = it_ref[0, 0, lo:hi].reshape(nx, m_c, 1)

    sx = _window3(xs_ref[0, 0, :], nx, m_c).reshape(nx, 1, 3 * m_c)
    sy = _window3(ys_ref[0, 0, :], nx, m_c).reshape(nx, 1, 3 * m_c)
    sz = _window3(zs_ref[0, 0, :], nx, m_c).reshape(nx, 1, 3 * m_c)
    sid = _window3(is_ref[0, 0, :], nx, m_c).reshape(nx, 1, 3 * m_c)

    ddx, ddy, ddz = tx - sx, ty - sy, tz - sz
    r2 = ddx * ddx + ddy * ddy + ddz * ddz
    mask = (sid != tid) & (sid >= 0) & (tid >= 0) & (r2 < cutoff2) & (r2 > 0.0)
    r2s = jnp.where(mask, r2, 1.0)
    w = mask.astype(ddx.dtype)
    s = kernel.coeff(r2s) * w
    pot = kernel.potential(r2s) * w

    fx_ref[...] += (s * ddx).sum(-1).reshape(1, 1, nx * m_c)
    fy_ref[...] += (s * ddy).sum(-1).reshape(1, 1, nx * m_c)
    fz_ref[...] += (s * ddz).sum(-1).reshape(1, 1, nx * m_c)
    pot_ref[...] += pot.sum(-1).reshape(1, 1, nx * m_c)


@functools.partial(jax.jit, static_argnames=("nx", "m_c", "kernel", "cutoff2", "interpret"))
def xpencil_forces(planes: dict, slot_id: Array, *, nx: int, m_c: int,
                   kernel: PairKernel, cutoff2: float,
                   interpret: Optional[bool] = None
                   ) -> Tuple[Array, Array, Array, Array]:
    """Run the X-pencil kernel over padded planes.

    Args:
      planes: dict with "x","y","z" padded planes (nz+2, ny+2, (nx+2)*m_c).
      slot_id: matching int32 plane, -1 for empty slots.
      interpret: None = native on TPU, interpreter elsewhere (matching
        ``InteractionPlan.interpret``); bool forces the mode.
    Returns:
      (fx, fy, fz, pot), each (nz, ny, nx*m_c) over interior slots.
    """
    interpret = resolve_interpret(interpret)
    x = planes["x"]
    nzp, nyp, w = x.shape
    nz, ny = nzp - 2, nyp - 2
    row_block = pl.BlockSpec((1, 1, w), lambda z, y, k: (z + 1, y + 1, 0))
    nbr_block = pl.BlockSpec((1, 1, w), lambda z, y, k: (z + k // 3, y + k % 3, 0))
    out_block = pl.BlockSpec((1, 1, nx * m_c), lambda z, y, k: (z, y, 0))
    out_shape = jax.ShapeDtypeStruct((nz, ny, nx * m_c), x.dtype)

    body = functools.partial(_kernel, nx=nx, m_c=m_c, kernel=kernel,
                             cutoff2=float(cutoff2))
    fx, fy, fz, pot = pl.pallas_call(
        body,
        grid=(nz, ny, 9),
        in_specs=[row_block] * 4 + [nbr_block] * 4,
        out_specs=[out_block] * 4,
        out_shape=[out_shape] * 3 + [jax.ShapeDtypeStruct(
            (nz, ny, nx * m_c), x.dtype)],
        interpret=interpret,
    )(x, planes["y"], planes["z"], slot_id,
      x, planes["y"], planes["z"], slot_id)
    return fx, fy, fz, pot
