"""All-in-SM interaction kernel (paper §5.1) as a Pallas TPU kernel.

The paper stages a whole sub-box of cells plus its ghost ring in shared
memory. Halo blocks *overlap* between neighboring sub-boxes, which BlockSpec
tiling cannot express, so this kernel does what a production TPU kernel does
for halos: inputs stay in HBM (``MemorySpace.ANY``) and each program issues
explicit overlapping DMAs into VMEM scratch (``make_async_copy``) — the
literal analogue of the paper's dynamic-shared-memory copy-in, with all four
field copies in flight together.

  grid = (gz, gy, gx)            one program per sub-box (paper thread-block)
  scratch = 4 x VMEM (bz+2, by+2, (bx+2)*m_c)   the staged halo block
  outputs = non-overlapping (bz, by, bx*m_c) blocks.

The paper's verdict — the sub-box footprint kills occupancy — maps directly:
the staged halo is the whole per-step VMEM budget, so the pipeline has no
double-buffer head-room and the DMA latency is exposed. ``traffic.model``
quantifies this; the kernel exists to reproduce the schedule faithfully.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.interactions import PairKernel
from ._platform import resolve_interpret

Array = jnp.ndarray


def _window3_blk(blk: Array, b: int, m_c: int) -> Array:
    """(.., (bx+2)*m_c) halo rows -> (.., bx, 3*m_c) contiguous windows."""
    lead = blk.shape[:-1]
    cells = blk.reshape(*lead, b + 2, m_c)
    return jnp.concatenate(
        [cells[..., 0:b, :], cells[..., 1:b + 1, :], cells[..., 2:b + 2, :]],
        axis=-1)


def _kernel(xp, yp, zp, ip,             # HBM-resident padded planes
            fx_ref, fy_ref, fz_ref, pot_ref,
            sx, sy, sz, si, sems,       # VMEM scratch + DMA semaphores
            *, bx: int, by: int, bz: int, m_c: int,
            kernel: PairKernel, cutoff2: float):
    iz, iy, ix = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    z0, y0, x0 = iz * bz, iy * by, ix * bx * m_c
    dz_, dy_, dx_ = bz + 2, by + 2, (bx + 2) * m_c

    copies = []
    for j, (src, dst) in enumerate(((xp, sx), (yp, sy), (zp, sz), (ip, si))):
        cp = pltpu.make_async_copy(
            src.at[pl.ds(z0, dz_), pl.ds(y0, dy_), pl.ds(x0, dx_)],
            dst, sems.at[j])
        cp.start()
        copies.append(cp)
    for cp in copies:
        cp.wait()

    def inner(ref):
        v = ref[1:bz + 1, 1:by + 1, m_c:(bx + 1) * m_c]
        return v.reshape(bz, by, bx, m_c, 1)

    tx, ty, tz, tid = inner(sx), inner(sy), inner(sz), inner(si)

    fx = jnp.zeros((bz, by, bx, m_c), sx.dtype)
    fy = jnp.zeros_like(fx)
    fz = jnp.zeros_like(fx)
    pv = jnp.zeros_like(fx)
    for dz in (-1, 0, 1):
        for dy in (-1, 0, 1):
            sl = (slice(1 + dz, 1 + dz + bz), slice(1 + dy, 1 + dy + by))
            wx = _window3_blk(sx[sl], bx, m_c)[:, :, :, None, :]
            wy = _window3_blk(sy[sl], bx, m_c)[:, :, :, None, :]
            wz = _window3_blk(sz[sl], bx, m_c)[:, :, :, None, :]
            wi = _window3_blk(si[sl], bx, m_c)[:, :, :, None, :]
            ddx, ddy, ddz = tx - wx, ty - wy, tz - wz
            r2 = ddx * ddx + ddy * ddy + ddz * ddz
            mask = ((wi != tid) & (wi >= 0) & (tid >= 0)
                    & (r2 < cutoff2) & (r2 > 0.0))
            r2s = jnp.where(mask, r2, 1.0)
            w = mask.astype(ddx.dtype)
            s = kernel.coeff(r2s) * w
            fx += (s * ddx).sum(-1)
            fy += (s * ddy).sum(-1)
            fz += (s * ddz).sum(-1)
            pv += (kernel.potential(r2s) * w).sum(-1)

    fx_ref[...] = fx.reshape(bz, by, bx * m_c)
    fy_ref[...] = fy.reshape(bz, by, bx * m_c)
    fz_ref[...] = fz.reshape(bz, by, bx * m_c)
    pot_ref[...] = pv.reshape(bz, by, bx * m_c)


@functools.partial(jax.jit, static_argnames=("box", "m_c", "kernel", "cutoff2", "interpret"))
def allin_forces(planes: dict, slot_id: Array, *, box: Tuple[int, int, int],
                 m_c: int, kernel: PairKernel, cutoff2: float,
                 interpret: Optional[bool] = None
                 ) -> Tuple[Array, Array, Array, Array]:
    """Run the All-in-SM kernel. ``box`` = (bx, by, bz) interior sub-box;
    must divide the grid (``core.strategies.subbox_dims`` + divisor shrink).
    ``interpret=None`` resolves by platform (native on TPU, interpreter
    elsewhere), matching ``InteractionPlan.interpret``.
    Returns (fx, fy, fz, pot), each (nz, ny, nx*m_c)."""
    interpret = resolve_interpret(interpret)
    x = planes["x"]
    nzp, nyp, w = x.shape
    nz, ny = nzp - 2, nyp - 2
    nx = w // m_c - 2
    bx, by, bz = box
    assert nx % bx == 0 and ny % by == 0 and nz % bz == 0, (nx, ny, nz, box)
    gz, gy, gx = nz // bz, ny // by, nx // bx

    any_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
    out_block = pl.BlockSpec((bz, by, bx * m_c), lambda z, y, xk: (z, y, xk))
    out_shape = jax.ShapeDtypeStruct((nz, ny, nx * m_c), x.dtype)
    scratch = [pltpu.VMEM((bz + 2, by + 2, (bx + 2) * m_c), x.dtype)
               for _ in range(3)]
    scratch += [pltpu.VMEM((bz + 2, by + 2, (bx + 2) * m_c), slot_id.dtype),
                pltpu.SemaphoreType.DMA((4,))]

    body = functools.partial(_kernel, bx=bx, by=by, bz=bz, m_c=m_c,
                             kernel=kernel, cutoff2=float(cutoff2))
    return pl.pallas_call(
        body,
        grid=(gz, gy, gx),
        in_specs=[any_spec] * 4,
        out_specs=[out_block] * 4,
        out_shape=[out_shape] * 4,
        scratch_shapes=scratch,
        interpret=interpret,
    )(x, planes["y"], planes["z"], slot_id)
