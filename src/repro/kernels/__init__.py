"""Pallas TPU kernels for the paper's hot spots (DESIGN.md §2-3).

xpencil      the paper's X-pencil schedule (BlockSpec pencil staging)
allin        the paper's All-in-SM schedule (manual halo DMA into VMEM)
prefix_sum   the paper's §6 scan (VMEM, 2h-3 vector passes)
window_attn  the technique transferred to LM local attention

Each kernel has a pure-jnp oracle in ref.py and a jit wrapper in ops.py.
"""

from .ops import (allin_interactions, prefix_sum, window_attention,
                  xpencil_interactions)

__all__ = ["allin_interactions", "prefix_sum", "window_attention",
           "xpencil_interactions"]
