"""Pallas TPU kernels for the paper's hot spots (DESIGN.md §2-3).

xpencil      the paper's X-pencil schedule (BlockSpec pencil staging)
allin        the paper's All-in-SM schedule (manual halo DMA into VMEM)
prefix_sum   the paper's §6 scan (VMEM, 2h-3 vector passes)
window_attn  the technique transferred to LM local attention

Each kernel has a pure-jnp oracle in ref.py and a jit wrapper in ops.py.

The interaction kernels are wired into the plan/execute front door
(``repro.core.api``): importing this package registers them as the
``"pallas"`` backend under the same strategy names as their pure-JAX
oracles, so

    plan(domain, kernel, positions=pos, strategy="xpencil",
         backend="pallas").execute(ParticleState(pos))

runs the Pallas X-pencil kernel (natively on TPU, interpret mode elsewhere)
through exactly the API users already select strategies with.
"""

from ..core.api import InteractionPlan, ParticleState, register_backend
from ..core.binning import CellBins, PackedRows, SfcClusters
from .ops import (allin_interactions, cell_sfc_interactions, prefix_sum,
                  window_attention, xpencil_interactions,
                  xpencil_packed_interactions, xpencil_sparse_interactions)

__all__ = ["allin_interactions", "cell_sfc_interactions", "prefix_sum",
           "window_attention", "xpencil_interactions",
           "xpencil_packed_interactions", "xpencil_sparse_interactions"]


# -- plan/execute backend registration (normalized signature) ---------------

@register_backend("pallas", "xpencil", compact=True)
def _pallas_xpencil(plan: InteractionPlan, bins: CellBins,
                    state: ParticleState):
    if plan.compact:
        return xpencil_sparse_interactions(plan.domain, bins, plan.kernel,
                                           plan.max_active,
                                           interpret=plan.interpret)
    return xpencil_interactions(plan.domain, bins, plan.kernel,
                                interpret=plan.interpret)


@register_backend("pallas", "allin")
def _pallas_allin(plan: InteractionPlan, bins: CellBins,
                  state: ParticleState):
    return allin_interactions(plan.domain, bins, plan.kernel, plan.box,
                              interpret=plan.interpret)


@register_backend("pallas", "xpencil", compact=True, layout="packed")
def _pallas_xpencil_packed(plan: InteractionPlan, packed: PackedRows,
                           state: ParticleState):
    return xpencil_packed_interactions(
        plan.domain, packed, plan.kernel,
        max_active=plan.max_active if plan.compact else None,
        interpret=plan.interpret)


@register_backend("pallas", "cell_dense", compact=True, layout="sfc")
def _pallas_cell_sfc(plan: InteractionPlan, sfc: SfcClusters,
                     state: ParticleState):
    # compact=True is a no-op for the SFC layout: the compressed pair list
    # IS the compaction (mirrors the reference registration in core.api).
    return cell_sfc_interactions(plan.domain, sfc, plan.kernel,
                                 interpret=plan.interpret)
