"""Pencil-pattern sliding-window attention (flash-style) Pallas kernel.

This is the paper's technique transferred to the LM side (DESIGN.md §4):
a cutoff radius over a 1-D token grid. Queries are the target particles, KV
blocks are the cells, the window is ``r_c``; the schedule is the X-pencil's:
the target block stays resident while the neighbor blocks inside the cutoff
stream through VMEM one at a time, innermost in the grid. Out-of-window work
is never *loaded*, not just masked — the cell-list property.

  grid = (B*H, nq, nw)   nw = number of KV blocks covering the window
  q block   (1, 1, blk, D)  at (b, h, qi)
  k/v block (1, 1, blk, D)  at (b, h//group, qi - (nw-1) + j)  (clamped)
  scratch   m, l, acc — the online-softmax state, persisted across j
            (the "registers" of the paper's pencil targets).

Causal + window mask, optional logit softcap (gemma2), GQA via head mapping.
Requires S % blk == 0; blk should be a multiple of 128 lanes on real TPUs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jnp.ndarray

NEG_INF = -1.0e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, blk: int, nw: int, window: int, softcap: float, scale: float):
    qi, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    c = qi - (nw - 1) + j                      # logical kv block (may be < 0)
    q = q_ref[0, 0].astype(jnp.float32) * scale        # (blk, D)
    k = k_ref[0, 0].astype(jnp.float32)                # (blk, D)
    s = q @ k.T                                        # (blk, blk) fp32
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * blk + jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0)
    k_pos = (jnp.maximum(c, 0) * blk
             + jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1))
    mask = (k_pos <= q_pos) & (q_pos - k_pos < window) & (c >= 0)
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)          # (blk, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                              # (blk, blk)
    l_new = l_prev * alpha + p.sum(-1, keepdims=True)
    acc_scr[...] = (acc_scr[...] * alpha
                    + p @ v_ref[0, 0].astype(jnp.float32))
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(j == nw - 1)
    def _fini():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "blk", "softcap",
                                             "interpret"))
def window_attention(q: Array, k: Array, v: Array, *, window: int,
                     blk: int = 128, softcap: float = 0.0,
                     interpret: bool | None = None) -> Array:
    """Sliding-window causal attention.

    Args:
      q: (B, H, S, D); k, v: (B, KH, S, D), H % KH == 0.
      window: tokens visible to each query (self included): k in
        (q - window, q].
      interpret: None = native on TPU, interpreter elsewhere.
    Returns:
      (B, H, S, D) in q's dtype.
    """
    from ._platform import resolve_interpret
    interpret = resolve_interpret(interpret)
    b, h, s, d = q.shape
    kh = k.shape[1]
    assert h % kh == 0 and s % blk == 0, (q.shape, k.shape, blk)
    group = h // kh
    nq = s // blk
    nw = (window - 1) // blk + 2      # blocks covering (q - window, q]
    nw = min(nw, nq)
    scale = 1.0 / (d ** 0.5)

    def kv_idx(bh, qi, j):
        c = jnp.maximum(qi - (nw - 1) + j, 0)
        return (bh // h, (bh % h) // group, c, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, blk=blk, nw=nw, window=window,
                          softcap=float(softcap), scale=scale),
        grid=(b * h, nq, nw),
        in_specs=[
            pl.BlockSpec((1, 1, blk, d),
                         lambda bh, qi, j: (bh // h, bh % h, qi, 0)),
            pl.BlockSpec((1, 1, blk, d), kv_idx),
            pl.BlockSpec((1, 1, blk, d), kv_idx),
        ],
        out_specs=pl.BlockSpec((1, 1, blk, d),
                               lambda bh, qi, j: (bh // h, bh % h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk, 1), jnp.float32),
            pltpu.VMEM((blk, 1), jnp.float32),
            pltpu.VMEM((blk, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out
