"""Public jit'd entry points for the Pallas kernels.

Backend dispatch: ``interpret=None`` (default) runs the kernel body natively
on TPU and in interpret mode everywhere else — so the same call sites work in
CPU tests/dry-runs and on real hardware. The model/engine layers default to
the pure-JAX paths and opt into these kernels via ``implementation="pallas"``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

import numpy as np

from ..core.binning import (EMPTY_POS, CellBins, PackedRows, SfcClusters,
                            dense_to_particles, full_pencil_occupancy,
                            packed_to_particles, pencil_occupancy,
                            sfc_cluster_tables, sfc_slot_tables,
                            sfc_to_particles)
from ..core.domain import Domain
from ..core.interactions import PairKernel
from ._platform import resolve_interpret as _interpret
from .allin import allin_forces
from .prefix_sum import prefix_sum as _prefix_sum
from .sfc import cell_sfc_forces
from .window_attn import window_attention as _window_attention
from .xpencil import (xpencil_forces, xpencil_packed_forces,
                      xpencil_sparse_forces)

Array = jnp.ndarray


def xpencil_interactions(domain: Domain, bins: CellBins, kernel: PairKernel,
                         interpret: Optional[bool] = None
                         ) -> Tuple[Array, Array]:
    """X-pencil kernel -> per-particle (forces (N,3), potential (N,))."""
    fx, fy, fz, pot = xpencil_forces(
        bins.planes, bins.slot_id, nx=domain.nx, m_c=bins.m_c, kernel=kernel,
        cutoff2=float(domain.cutoff) ** 2, interpret=_interpret(interpret))
    return _to_particles(domain, bins, fx, fy, fz, pot)


def xpencil_sparse_interactions(domain: Domain, bins: CellBins,
                                kernel: PairKernel, max_active: int,
                                interpret: Optional[bool] = None
                                ) -> Tuple[Array, Array]:
    """Compacted X-pencil kernel -> per-particle (forces, potential).

    Builds the pencil occupancy summary from the bin counts (traceable),
    runs the scalar-prefetch kernel over the ``max_active``-bounded active
    list, and scatters the compact rows back into dense planes. If more
    than ``max_active`` pencils are active the extra ones are *dropped* —
    callers detect that via ``InteractionPlan.check_overflow`` and replan,
    exactly like an overflowing ``m_c``.
    """
    nx, ny, nz = domain.ncells
    occ = pencil_occupancy(domain, bins.counts, max_active)
    compact = xpencil_sparse_forces(
        bins.planes, bins.slot_id, occ.active, nx=nx, ny=ny, m_c=bins.m_c,
        kernel=kernel, cutoff2=float(domain.cutoff) ** 2,
        interpret=_interpret(interpret))
    idx = occ.scatter_indices()

    def scatter(rows: Array) -> Array:      # (max_active, nx*m_c) -> dense
        dense = jnp.zeros((nz * ny, nx * bins.m_c), rows.dtype)
        return dense.at[idx].set(rows, mode="drop").reshape(
            nz, ny, nx * bins.m_c)

    fx, fy, fz, pot = (scatter(r) for r in compact)
    return _to_particles(domain, bins, fx, fy, fz, pot)


def xpencil_packed_interactions(domain: Domain, packed: PackedRows,
                                kernel: PairKernel,
                                max_active: Optional[int] = None,
                                interpret: Optional[bool] = None
                                ) -> Tuple[Array, Array]:
    """Packed-row X-pencil kernel -> per-particle (forces, potential).

    Iterates every pencil row when ``max_active`` is None, or the
    occupancy-compacted active list bounded by ``max_active`` otherwise
    (the packed and compacted axes compose). Compact kernel rows scatter
    back into packed ``(nz * ny, row_cap)`` planes, then unpack to
    particle order; overflow of either bound is the caller's replan
    contract (``InteractionPlan.check_overflow``).
    """
    nx, ny, nz = domain.ncells
    occ = (full_pencil_occupancy(domain) if max_active is None
           else pencil_occupancy(domain, packed.counts, max_active))
    compact = xpencil_packed_forces(
        packed.planes, packed.slot_id, packed.slot_cell,
        packed.cell_offsets, occ.active, nx=nx, ny=ny, m_c=packed.m_c,
        row_cap=packed.row_cap, kernel=kernel,
        cutoff2=float(domain.cutoff) ** 2, interpret=_interpret(interpret))
    idx = occ.scatter_indices()

    def scatter(rows: Array) -> Array:      # (n_rows, row_cap) -> packed
        dense = jnp.zeros((nz * ny, packed.row_cap), rows.dtype)
        return dense.at[idx].set(rows, mode="drop")

    fx, fy, fz, pot = (scatter(r) for r in compact)
    return packed_to_particles(domain, packed, fx, fy, fz, pot)


def cell_sfc_interactions(domain: Domain, sfc: SfcClusters,
                          kernel: PairKernel,
                          interpret: Optional[bool] = None
                          ) -> Tuple[Array, Array]:
    """SFC cluster-pair kernel -> per-particle (forces (N,3), potential (N,)).

    Gathers the cluster target tiles (plus one all-sentinel ghost row the
    pair-list padding decodes to), stages the flattened padded planes with
    one appended sentinel cell, and runs the compressed-pair-list Pallas
    kernel (``kernels.sfc``). Clusters with no kept pair are never visited
    by the grid, so their output rows are explicitly zeroed from the kept
    mask before scattering back to particle order — identical to the
    reference runner, whose fully-masked stencil terms accumulate exact
    (+0.0) zeros.
    """
    bins = sfc.bins
    m_c, csize = bins.m_c, sfc.csize
    tables = sfc_cluster_tables(domain, csize, sfc.curve)
    tgt_base, src_base = sfc_slot_tables(domain, m_c, csize, sfc.curve)
    n_clusters = tables.n_clusters
    total = bins.slot_id.size
    tile_w = csize * m_c

    def ext(plane: Array, fill) -> Array:   # flatten + one sentinel cell
        flat = plane.reshape(-1)
        return jnp.concatenate(
            [flat, jnp.full((m_c,), fill, flat.dtype)])[None, :]

    flats = {"x": ext(bins.planes["x"], EMPTY_POS),
             "y": ext(bins.planes["y"], EMPTY_POS),
             "z": ext(bins.planes["z"], EMPTY_POS),
             "id": ext(bins.slot_id, -1)}

    rank = jnp.arange(m_c, dtype=jnp.int32)
    tidx = (jnp.asarray(tgt_base)[:, :, None] + rank).reshape(
        n_clusters, tile_w)

    def tile(flat: Array, fill) -> Array:   # gather tiles + ghost row
        rows = flat[0][tidx]
        ghost = jnp.full((1, tile_w), fill, rows.dtype)
        return jnp.concatenate([rows, ghost], axis=0)

    tiles = {"x": tile(flats["x"], EMPTY_POS),
             "y": tile(flats["y"], EMPTY_POS),
             "z": tile(flats["z"], EMPTY_POS),
             "id": tile(flats["id"], -1)}

    src_off = np.concatenate(
        [np.asarray(src_base).reshape(-1),
         np.full((27 * csize,), total, np.int32)]).astype(np.int32)

    codes = sfc.codes.astype(jnp.int32)
    first = jnp.concatenate(
        [jnp.ones((1,), jnp.int32),
         ((codes[1:] >> 5) != (codes[:-1] >> 5)).astype(jnp.int32)])

    fx, fy, fz, pot = cell_sfc_forces(
        tiles, flats, codes, first, jnp.asarray(src_off), csize=csize,
        m_c=m_c, kernel=kernel, cutoff2=float(domain.cutoff) ** 2,
        interpret=_interpret(interpret))

    kept = jnp.zeros((n_clusters + 1,), jnp.int32).at[codes >> 5].add(1)
    has = (kept[:n_clusters] > 0)[:, None]
    fx, fy, fz, pot = (jnp.where(has, o[:n_clusters], 0.0)
                       for o in (fx, fy, fz, pot))
    return sfc_to_particles(domain, sfc, fx, fy, fz, pot)


def allin_interactions(domain: Domain, bins: CellBins, kernel: PairKernel,
                       box, interpret: Optional[bool] = None
                       ) -> Tuple[Array, Array]:
    """All-in-SM kernel -> per-particle (forces, potential)."""
    fx, fy, fz, pot = allin_forces(
        bins.planes, bins.slot_id, box=tuple(box), m_c=bins.m_c,
        kernel=kernel, cutoff2=float(domain.cutoff) ** 2,
        interpret=_interpret(interpret))
    return _to_particles(domain, bins, fx, fy, fz, pot)


def _to_particles(domain, bins, fx, fy, fz, pot):
    return dense_to_particles(domain, bins, fx, fy, fz, pot)


def prefix_sum(x: Array, interpret: Optional[bool] = None) -> Array:
    """Paper §6 prefix sum (VMEM kernel)."""
    return _prefix_sum(x, interpret=_interpret(interpret))


def window_attention(q: Array, k: Array, v: Array, *, window: int,
                     blk: int = 128, softcap: float = 0.0,
                     interpret: Optional[bool] = None) -> Array:
    """Pencil-pattern sliding-window attention (see window_attn.py)."""
    return _window_attention(q, k, v, window=window, blk=blk,
                             softcap=softcap, interpret=_interpret(interpret))
