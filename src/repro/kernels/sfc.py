"""SFC cluster-pair interaction kernel (compressed neighbor list) in Pallas.

The dense/compacted kernels iterate a *grid-shaped* schedule (every pencil,
or every active pencil); this kernel iterates the **compressed cluster-pair
list** of the SFC layout (``binning.SfcClusters``) directly:

  grid = (pair_cap,)
    one program per compressed pair code ``cluster * 32 + k`` — the codes
    array is *scalar-prefetched* (``pltpu.PrefetchScalarGridSpec``), so the
    output/target BlockSpec index maps decode the cluster id from the code
    before each step and DMA exactly that cluster's ``csize * m_c`` target
    tile. Codes are sorted (cluster-major, k-minor), so consecutive
    programs of one cluster revisit the same resident output block and
    accumulate stencil terms in ascending-k order — the exact float
    association of the dense Par-Cell sweep, which is what makes the
    kernel bit-identical to ``cell_dense`` (see strategies.cell_sfc).

  Source staging: the padded SoA planes are staged whole (flattened, plus
  one appended always-empty sentinel cell); per stencil slot k and cluster
  cell j, the scalar-prefetched slot-offset table gives the flat base of
  the k-shifted cell and a dynamic ``pl.ds`` slice reads its ``m_c`` slots
  from the staged block — the cluster-tile-from-shared-memory evaluation
  of the CSCS follow-up. Sentinel pair codes (pair-list padding) decode to
  the ghost cluster row, whose targets and sources are all sentinels, so
  they accumulate exact zeros and the row is stripped by the wrapper.

VMEM note: staging the whole padded planes costs ``4 * total`` floats —
fine at the repo's benchmark scales (a division-12 box at m_c=16 is
~700 KB); a production-scale TPU variant would DMA per-cluster halo tiles
instead. Interpret mode (CPU tests) is unaffected.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.interactions import PairKernel
from ._platform import resolve_interpret

Array = jnp.ndarray


def _sfc_kernel(codes_ref, first_ref, off_ref,       # scalar-prefetched
                xt_ref, yt_ref, zt_ref, it_ref,      # target cluster tile
                xs_ref, ys_ref, zs_ref, is_ref,      # staged flat planes
                fx_ref, fy_ref, fz_ref, pot_ref,
                *, csize: int, m_c: int, kernel: PairKernel,
                cutoff2: float):
    p = pl.program_id(0)
    code = codes_ref[p]
    a = code >> 5
    k = code & 31

    @pl.when(first_ref[p] == 1)
    def _init():                 # first pair of this cluster: zero the tile
        fx_ref[...] = jnp.zeros_like(fx_ref)
        fy_ref[...] = jnp.zeros_like(fy_ref)
        fz_ref[...] = jnp.zeros_like(fz_ref)
        pot_ref[...] = jnp.zeros_like(pot_ref)

    for j in range(csize):       # static unroll over the cluster's cells
        base = off_ref[(a * 27 + k) * csize + j]
        sx = xs_ref[0, pl.ds(base, m_c)]
        sy = ys_ref[0, pl.ds(base, m_c)]
        sz = zs_ref[0, pl.ds(base, m_c)]
        sid = is_ref[0, pl.ds(base, m_c)]
        lo = j * m_c
        tx = xt_ref[0, lo:lo + m_c]
        ty = yt_ref[0, lo:lo + m_c]
        tz = zt_ref[0, lo:lo + m_c]
        tid = it_ref[0, lo:lo + m_c]

        ddx = tx[:, None] - sx[None, :]
        ddy = ty[:, None] - sy[None, :]
        ddz = tz[:, None] - sz[None, :]
        r2 = ddx * ddx + ddy * ddy + ddz * ddz
        mask = ((sid[None, :] != tid[:, None]) & (sid[None, :] >= 0)
                & (tid[:, None] >= 0) & (r2 < cutoff2) & (r2 > 0.0))
        r2s = jnp.where(mask, r2, 1.0)
        w = mask.astype(ddx.dtype)
        s = kernel.coeff(r2s) * w
        pot = kernel.potential(r2s) * w
        fx_ref[0, lo:lo + m_c] += (s * ddx).sum(-1)
        fy_ref[0, lo:lo + m_c] += (s * ddy).sum(-1)
        fz_ref[0, lo:lo + m_c] += (s * ddz).sum(-1)
        pot_ref[0, lo:lo + m_c] += pot.sum(-1)


@functools.partial(jax.jit, static_argnames=("csize", "m_c", "kernel",
                                             "cutoff2", "interpret"))
def cell_sfc_forces(tiles: dict, flats: dict, codes: Array, first: Array,
                    src_off: Array, *, csize: int, m_c: int,
                    kernel: PairKernel, cutoff2: float,
                    interpret: Optional[bool] = None
                    ) -> Tuple[Array, Array, Array, Array]:
    """Run the SFC pair-list kernel over the compressed codes.

    Args:
      tiles: field name ("x","y","z","id") -> ``(n_clusters + 1,
        csize * m_c)`` target cluster tiles, last row the all-sentinel
        ghost cluster the pair-list padding decodes to.
      flats: same fields -> ``(1, total + m_c)`` flattened padded planes
        with one appended sentinel cell.
      codes: (pair_cap,) int32 sorted compressed pair codes.
      first: (pair_cap,) int32, 1 where a program is its cluster's first
        pair (zero-initializes the resident output tile).
      src_off: ((n_clusters + 1) * 27 * csize,) int32 flat slot base of
        cell j of cluster a shifted by stencil k (ghost row -> sentinel).
    Returns:
      (fx, fy, fz, pot), each ``(n_clusters + 1, csize * m_c)`` — rows of
      clusters with no kept pair are *unwritten* (the wrapper masks them).
    """
    interpret = resolve_interpret(interpret)
    xt = tiles["x"]
    n_rows, tile_w = xt.shape
    flat_w = flats["x"].shape[-1]

    def tile_map(p, codes, first, off):
        return (codes[p] >> 5, 0)

    tile_block = pl.BlockSpec((1, tile_w), tile_map)
    flat_block = pl.BlockSpec((1, flat_w), lambda p, codes, first, off: (0, 0))
    out_shape = jax.ShapeDtypeStruct((n_rows, tile_w), xt.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(codes.shape[0],),
        in_specs=[tile_block] * 4 + [flat_block] * 4,
        out_specs=[tile_block] * 4,
    )
    body = functools.partial(_sfc_kernel, csize=csize, m_c=m_c,
                             kernel=kernel, cutoff2=float(cutoff2))
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=[out_shape] * 4,
        interpret=interpret,
    )(codes.astype(jnp.int32), first.astype(jnp.int32),
      src_off.astype(jnp.int32),
      tiles["x"], tiles["y"], tiles["z"], tiles["id"],
      flats["x"], flats["y"], flats["z"], flats["id"])
