"""Shared interpret-mode resolution for every Pallas kernel entry point.

``interpret=None`` (the default everywhere) means: run the kernel natively
on TPU, fall back to the Pallas interpreter elsewhere — the same rule
``InteractionPlan.interpret`` uses, so calling a kernel directly behaves
like calling it through the plan API. Pass an explicit bool to override
(tests force ``interpret=True`` for determinism off-TPU).
"""

from __future__ import annotations

from typing import Optional

import jax


def resolve_interpret(flag: Optional[bool]) -> bool:
    if flag is None:
        return jax.default_backend() != "tpu"
    return bool(flag)
