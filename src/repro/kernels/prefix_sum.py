"""The paper's §6 prefix sum as a Pallas VMEM kernel.

The CUDA version runs one thread-block over a shared-memory array with
``2h - 3`` barriers. The TPU analogue: one program owns the array in VMEM and
each barrier-delimited level becomes one *vectorized pass* — on a 2-D SIMD
machine the per-level index set {js-1, 2js-1, ...} is a stride mask, and
"x[idN] += x[idN - jsd2]" is a masked add of the array shifted right by jsd2.
Shifts are static per level (N is static), so the level loop unrolls at trace
time into 2h-3 shift+mask+add passes, all VMEM-resident: the same memory-
access structure the paper optimizes for (each level touches each element at
most once, no extra scratch).

Wrap-around garbage from the roll lands only at masked positions (the update
set has idN >= js - 1 >= shift), mirroring the paper's ``idN < N`` guard.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jnp.ndarray


def _levels(n: int):
    """(shift, modulus, first_index) per barrier-delimited level, paper order."""
    out = []
    js = 2
    while js <= n:
        out.append((js // 2, js, js - 1))
        js *= 2
    js = max(4, js // 2)
    while js > 1:
        jsd2 = js // 2
        first = js + jsd2 - 1
        if first < n:
            out.append((jsd2, js, first))
        js = jsd2
    return out


def _kernel(x_ref, o_ref, *, n: int):
    x = x_ref[...]  # (1, n)
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    for shift, js, first in _levels(n):
        shifted = jnp.roll(x, shift, axis=-1)
        mask = (idx % js == (first % js)) & (idx >= first)
        x = x + jnp.where(mask, shifted, jnp.zeros_like(x))
    o_ref[...] = x


@functools.partial(jax.jit, static_argnames=("interpret",))
def prefix_sum(x: Array, interpret: bool | None = None) -> Array:
    """Inclusive prefix sum of a rank-1 array (paper §6 schedule).

    The whole array must fit in VMEM (the paper's setting: the per-cell count
    array of one sub-box). Larger arrays belong to the host-level scan.
    ``interpret=None`` resolves by platform (native on TPU).
    """
    from ._platform import resolve_interpret
    interpret = resolve_interpret(interpret)
    n = x.shape[0]
    out = pl.pallas_call(
        functools.partial(_kernel, n=n),
        in_specs=[pl.BlockSpec((1, n), lambda: (0, 0))],
        out_specs=pl.BlockSpec((1, n), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n), x.dtype),
        interpret=interpret,
    )(x.reshape(1, n))
    return out.reshape(n)
