"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

The particle-kernel oracles are the strategy references from ``core`` (the
kernels implement the *same schedule*, so the shared oracle is the point);
``prefix_sum`` is checked against ``jnp.cumsum`` (not against the paper's own
jnp implementation, to keep the oracle independent); ``window_attention``
against dense masked attention in fp32.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from ..core.binning import CellBins
from ..core.domain import Domain
from ..core.interactions import PairKernel
from ..core import strategies as S

Array = jnp.ndarray


def xpencil_ref(domain: Domain, bins: CellBins, kernel: PairKernel
                ) -> Tuple[Array, Array, Array, Array]:
    """(nz, ny, nx*m_c) interior force/potential planes."""
    nx, ny, nz = domain.ncells
    out = S.xpencil(domain, bins, kernel)
    return tuple(o.reshape(nz, ny, nx * bins.m_c) for o in out)


def allin_ref(domain: Domain, bins: CellBins, kernel: PairKernel,
              box) -> Tuple[Array, Array, Array, Array]:
    nx, ny, nz = domain.ncells
    out = S.allin(domain, bins, kernel, box=box)
    return tuple(o.reshape(nz, ny, nx * bins.m_c) for o in out)


def prefix_sum_ref(x: Array) -> Array:
    return jnp.cumsum(x, axis=-1, dtype=x.dtype)


def window_attention_ref(q: Array, k: Array, v: Array, *, window: int,
                         softcap: float = 0.0) -> Array:
    """Dense masked local attention, fp32 throughout."""
    b, h, s, d = q.shape
    kh = k.shape[1]
    group = h // kh
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf)
    scores = scores / (d ** 0.5)
    if softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = (kpos <= qpos) & (qpos - kpos < window)
    scores = jnp.where(mask, scores, -1e30)
    p = jnp.exp(scores - scores.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)
